"""Stream-vs-batch differential tests: any chunking of the reference fed
through a ``StreamSession`` must reproduce the offline engine bitwise.

The invariant under test is the acceptance bar of the streaming subsystem:
``engine.stream(...)`` fed an arbitrary partition of the reference equals
``engine.sdtw(..., return_spans=True)`` / ``search_topk`` on the
materialized array — distances, spans, and top-K heaps, bitwise for
int32 — including ragged query batches, prune on/off, mid-stream
snapshot/restore, non-destructive polling, and the Pallas feed path.
The 8-device sharded session is §11 of ``_distributed_check.py``.
"""
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sdtw, stream
from repro.core.sdtw import sdtw_chunked
from repro.search import EnvelopeCache, chunk_envelope, search_topk
from repro.stream import AlertEvent, StreamSession

GOLDEN = pathlib.Path(__file__).parent / "golden" / "sdtw_stream_v1.npz"


def _feed(session, reference, parts):
    off = 0
    for p in parts:
        session.feed(np.asarray(reference)[off:off + p])
        off += p
    assert off == len(reference)
    return session


#: Partitions of a 257-sample reference that stress every boundary case:
#: one shot, tile-aligned, single samples, tiny head, unaligned runs.
PARTITIONS_257 = [[257], [32] * 8 + [1], [1] * 257, [3, 254],
                  [100, 100, 57], [64, 1, 64, 1, 127]]


@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_stream_spans_match_engine_any_partition(metric, dtype, rng):
    """Distances/starts/ends equal the offline engine bitwise for every
    partition (integer-valued float32 is exact, so bitwise there too)."""
    q = rng.integers(-40, 40, (4, 10)).astype(dtype)
    r = rng.integers(-40, 40, 257).astype(dtype)
    want = sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric,
                return_spans=True)
    want = tuple(np.asarray(x) for x in want)
    for parts in PARTITIONS_257:
        s = _feed(stream(q, metric=metric, chunk=32, return_spans=True),
                  r, parts)
        res = s.results()
        got = (np.asarray(res.distances), np.asarray(res.starts),
               np.asarray(res.positions))
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b, err_msg=str(parts[:3]))


@pytest.mark.parametrize("excl_mode", ["end", "span"])
def test_stream_topk_matches_offline(excl_mode, rng):
    """The streamed heap equals the offline chunked top-K (and
    ``search_topk(prune=False)``) bitwise, both suppression modes."""
    q = rng.integers(-8, 8, (3, 8)).astype(np.int32)   # tie-heavy range
    r = rng.integers(-8, 8, 257).astype(np.int32)
    want = sdtw_chunked(jnp.asarray(q), jnp.asarray(r), chunk=32, top_k=3,
                        excl_zone=4, excl_mode=excl_mode, return_spans=True)
    wd, ws, we = (np.asarray(x) for x in want)
    sr = search_topk(q, r, k=3, chunk=32, excl_zone=4, excl_mode=excl_mode,
                     prune=False)
    np.testing.assert_array_equal(np.asarray(sr.distances), wd)
    for parts in ([257], [13] * 19 + [10], [200, 57]):
        s = _feed(stream(q, chunk=32, top_k=3, excl_zone=4,
                         excl_mode=excl_mode, return_spans=True), r, parts)
        res = s.results()
        np.testing.assert_array_equal(np.asarray(res.distances), wd)
        np.testing.assert_array_equal(np.asarray(res.starts), ws)
        np.testing.assert_array_equal(np.asarray(res.positions), we)


def test_stream_results_polling_is_nondestructive(rng):
    """results() applies the buffered tail to a *copy*: polling after
    every feed never changes the final answer, and each poll equals the
    offline answer over the samples seen so far."""
    q = rng.integers(-20, 20, (2, 6)).astype(np.int32)
    r = rng.integers(-20, 20, 90).astype(np.int32)
    s = stream(q, chunk=16, return_spans=True)
    seen = 0
    for p in (7, 20, 3, 40, 20):
        s.feed(r[seen:seen + p])
        seen += p
        res = s.results()
        want = sdtw(jnp.asarray(q), jnp.asarray(r[:seen]),
                    return_spans=True)
        np.testing.assert_array_equal(np.asarray(res.distances),
                                      np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(res.starts),
                                      np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(res.positions),
                                      np.asarray(want[2]))
        assert res.samples == seen


def test_stream_flush_midstream_keeps_streaming(rng):
    """A destructive mid-stream flush (carry exits at the true boundary
    via the clen lane) leaves distances/spans exact afterwards."""
    q = rng.integers(-20, 20, (3, 7)).astype(np.int32)
    r = rng.integers(-20, 20, 123).astype(np.int32)
    want = tuple(np.asarray(x) for x in
                 sdtw(jnp.asarray(q), jnp.asarray(r), return_spans=True))
    s = stream(q, chunk=16, return_spans=True)
    s.feed(r[:37]).flush()          # mid-tile boundary
    s.feed(r[37:41]).flush()        # tiny follow-up
    s.feed(r[41:])
    res = s.results()
    got = (np.asarray(res.distances), np.asarray(res.starts),
           np.asarray(res.positions))
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_stream_pallas_path_matches(rng):
    """The Pallas feed path (kernel carry entry/exit with traced ref_len)
    equals the rowscan session and the offline engine bitwise."""
    q = rng.integers(-10, 10, (3, 8)).astype(np.int32)
    r = rng.integers(-10, 10, 137).astype(np.int32)
    want = tuple(np.asarray(x) for x in
                 sdtw(jnp.asarray(q), jnp.asarray(r), return_spans=True))
    for parts in ([137], [50, 50, 37], [9] * 15 + [2]):
        s = _feed(stream(q, chunk=32, impl="pallas", return_spans=True,
                         block_q=2, block_m=64), r, parts)
        res = s.results()
        got = (np.asarray(res.distances), np.asarray(res.starts),
               np.asarray(res.positions))
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b, err_msg=str(parts[:2]))
    # positions-only session: end lane rides the kernel carry untaxed
    s = _feed(stream(q, chunk=32, impl="pallas", return_positions=True,
                     block_q=2, block_m=64), r, [137])
    res = s.results()
    np.testing.assert_array_equal(np.asarray(res.distances), want[0])
    np.testing.assert_array_equal(np.asarray(res.positions), want[2])


def test_pruned_stream_equals_exact(rng):
    """Online LB pruning skips tiles yet the heap equals the exact
    streamed heap — the admissibility argument, online."""
    q = rng.integers(-5, 5, (2, 8)).astype(np.int32)
    r = np.full(512, 1000, np.int32)
    r[40:60] = rng.integers(-5, 5, 20)
    r[100:130] = rng.integers(-6, 6, 30)
    r[400:420] = rng.integers(-5, 5, 20)
    want = sdtw_chunked(jnp.asarray(q), jnp.asarray(r), chunk=32, top_k=2,
                        return_spans=True)
    wd, ws, we = (np.asarray(x) for x in want)
    s = _feed(stream(q, chunk=32, top_k=2, return_spans=True, prune=True),
              r, [50] * 10 + [12])
    res = s.results()
    assert res.tiles_pruned > 0, "workload built to prune, but nothing was"
    assert res.tiles_processed < res.tiles_total
    np.testing.assert_array_equal(np.asarray(res.distances), wd)
    np.testing.assert_array_equal(np.asarray(res.starts), ws)
    np.testing.assert_array_equal(np.asarray(res.positions), we)


def test_pruned_stream_extends_envelope_cache(rng):
    """The streamed per-tile envelope lands in the shared cache: an
    offline ``search_topk`` against the materialized reference afterwards
    *hits* instead of recomputing, and the entry is bitwise what
    ``chunk_envelope`` computes."""
    q = rng.integers(-30, 30, (2, 8)).astype(np.int32)
    r = rng.integers(-30, 30, 300).astype(np.int32)
    cache = EnvelopeCache()
    s = stream(q, chunk=32, top_k=2, prune=True, cache=cache,
               ref_key="live-ecg")
    _feed(s, r, [90, 90, 120]).flush()
    env = cache.peek(("live-ecg", False), 32)
    assert env is not None
    mins, maxs = chunk_envelope(jnp.asarray(r), 32)
    np.testing.assert_array_equal(np.asarray(env[0]), np.asarray(mins))
    np.testing.assert_array_equal(np.asarray(env[1]), np.asarray(maxs))
    hits0 = cache.hits
    sr = search_topk(q, r, k=2, chunk=32, cache=cache, ref_key="live-ecg")
    assert cache.hits == hits0 + 1
    res = s.results()
    np.testing.assert_array_equal(np.asarray(res.distances),
                                  np.asarray(sr.distances))


def test_pruned_restore_into_fresh_cache_keeps_full_envelope(rng):
    """Restoring a pruned session in a *new process* (fresh cache) must
    install the whole streamed envelope prefix, not extend from
    mid-stream — otherwise offline reuse would see a truncated entry."""
    q = rng.integers(-30, 30, (2, 8)).astype(np.int32)
    r = rng.integers(-30, 30, 192).astype(np.int32)
    s1 = stream(q, chunk=32, top_k=2, prune=True, cache=EnvelopeCache(),
                ref_key="ft")
    s1.feed(r[:96])
    fresh = EnvelopeCache()                 # "new process"
    s2 = StreamSession.restore(s1.snapshot(), cache=fresh)
    s2.feed(r[96:]).flush()
    env = fresh.peek(("ft", False), 32)
    mins, maxs = chunk_envelope(jnp.asarray(r), 32)
    np.testing.assert_array_equal(np.asarray(env[0]), np.asarray(mins))
    np.testing.assert_array_equal(np.asarray(env[1]), np.asarray(maxs))


def test_envelope_cache_survives_restreams_and_partial_streams(rng):
    """Cache-corruption regressions: (a) a second monitor on the same
    ref_key must not double the envelope entry; (b) an entry from a
    stream that stopped mid-reference must not gate an offline search
    over the full reference — ``envelope()`` validates the tile count
    and recomputes instead."""
    q = rng.integers(-30, 30, (2, 8)).astype(np.int32)
    r = rng.integers(-30, 30, 192).astype(np.int32)
    cache = EnvelopeCache()
    for _ in range(2):                      # re-run the same monitor
        s = stream(q, chunk=32, top_k=2, prune=True, cache=cache,
                   ref_key="mon")
        _feed(s, r, [192]).flush()
    env = cache.peek(("mon", False), 32)
    assert len(np.asarray(env[0])) == 6     # not 12
    ok = search_topk(q, r, k=2, chunk=32, cache=cache, ref_key="mon")
    want = search_topk(q, r, k=2, chunk=32, prune=False)
    np.testing.assert_array_equal(np.asarray(ok.distances)[:, 0],
                                  np.asarray(want.distances)[:, 0])
    # (b) half-streamed entry: offline search over the full reference
    cache2 = EnvelopeCache()
    s = stream(q, chunk=32, top_k=2, prune=True, cache=cache2,
               ref_key="half")
    s.feed(r[:96])
    assert len(np.asarray(cache2.peek(("half", False), 32)[0])) == 3
    res = search_topk(q, r, k=2, chunk=32, cache=cache2, ref_key="half")
    np.testing.assert_array_equal(np.asarray(res.distances)[:, 0],
                                  np.asarray(want.distances)[:, 0])
    # the stale 3-tile entry was replaced, not served
    assert len(np.asarray(cache2.peek(("half", False), 32)[0])) == 6


def test_pruned_ragged_tile_telemetry_adds_up(rng):
    """Per-tile counters: pruned + processed == total even when ragged
    buckets disagree on whether a tile was worth the DP."""
    qs = [rng.integers(-5, 5, 4).astype(np.int32),
          rng.integers(-5, 5, 40).astype(np.int32)]
    r = np.full(1024, 1000, np.int32)
    r[100:140] = rng.integers(-5, 5, 40)
    s = stream(qs, chunk=32, top_k=2, prune=True)
    _feed(s, r, [256] * 4)
    res = s.results()
    assert res.tiles_total == 32
    assert res.tiles_pruned + res.tiles_processed == res.tiles_total
    # exact sessions report every tile as processed
    s2 = _feed(stream(qs, chunk=32), r, [1024])
    r2 = s2.results()
    assert r2.tiles_processed == r2.tiles_total == 32
    # spans on a session that doesn't track them raises, not None-array
    with pytest.raises(ValueError, match="track spans"):
        r2.spans


def test_alert_threshold_fires_on_planted_pattern(rng):
    """Planting query 0 verbatim in the stream fires a distance-0 alert at
    the right end column; alerts surface via both the callback and the
    session's alert log, once per triggering tile."""
    q = rng.integers(-50, 50, (2, 10)).astype(np.int32)
    r = rng.integers(200, 400, 200).astype(np.int32)   # far from queries
    r[150:160] = q[0]
    events = []
    s = stream(q, chunk=25, alert_threshold=0, on_alert=events.append)
    _feed(s, r, [60] * 3 + [20]).flush()
    assert s.alerts == events
    assert len(events) == 1
    ev = events[0]
    assert isinstance(ev, AlertEvent)
    assert ev.query == 0 and ev.distance == 0 and ev.end == 159
    assert ev.tile_start <= ev.end < ev.tile_end
    # a span-tracking session reports where the match began, too
    events2 = []
    s2 = stream(q, chunk=25, alert_threshold=0, on_alert=events2.append,
                return_spans=True)
    _feed(s2, r, [200]).flush()
    assert events2 and events2[0].start == 150 and events2[0].end == 159


def test_snapshot_npz_roundtrip(tmp_path, rng):
    """snapshot() → np.savez → np.load → restore() continues bit-for-bit
    (the fault-tolerant serving loop)."""
    q = [rng.integers(-20, 20, L).astype(np.int32) for L in (5, 11, 7)]
    r = rng.integers(-20, 20, 150).astype(np.int32)
    s1 = stream(q, chunk=16, top_k=2, return_spans=True)
    s1.feed(r[:70])
    path = tmp_path / "session.npz"
    np.savez(path, **s1.snapshot())
    s2 = StreamSession.restore(dict(np.load(path, allow_pickle=False)))
    s1.feed(r[70:])
    s2.feed(r[70:])
    r1, r2 = s1.results(), s2.results()
    np.testing.assert_array_equal(np.asarray(r1.distances),
                                  np.asarray(r2.distances))
    np.testing.assert_array_equal(np.asarray(r1.starts),
                                  np.asarray(r2.starts))
    np.testing.assert_array_equal(np.asarray(r1.positions),
                                  np.asarray(r2.positions))
    # and the restored stream still equals the offline answer
    want = sdtw(q, jnp.asarray(r), chunk=16, top_k=2, return_spans=True)
    np.testing.assert_array_equal(np.asarray(r2.distances),
                                  np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(r2.positions),
                                  np.asarray(want[2]))


def test_sharded_session_single_device_mesh(rng):
    """The sharded session's full feed/harvest/carry-handback path on the
    default (1-device, on CPU) mesh: degenerate pipeline, same protocol.
    The real 8-device bitwise check is §11 of ``_distributed_check.py``."""
    from repro.stream import ShardedStreamSession
    q = rng.integers(-10, 10, (3, 6)).astype(np.int32)
    r = rng.integers(-10, 10, 97).astype(np.int32)
    s = stream(q, impl="sharded", chunk=8, top_k=2, return_spans=True)
    for off in range(0, 97, 23):
        s.feed(r[off:off + 23])
    res = s.results()
    want = sdtw(jnp.asarray(q), jnp.asarray(r), chunk=8, top_k=2,
                return_spans=True)
    np.testing.assert_array_equal(np.asarray(res.distances),
                                  np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(res.starts),
                                  np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(res.positions),
                                  np.asarray(want[2]))
    s2 = ShardedStreamSession.restore(s.snapshot())
    np.testing.assert_array_equal(np.asarray(s2.results().distances),
                                  np.asarray(res.distances))
    # plain-distance lane as well
    sp = stream(q, impl="sharded", chunk=8)
    sp.feed(r)
    np.testing.assert_array_equal(
        np.asarray(sp.results().distances),
        np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r), chunk=8,
                        impl="chunked")))
    # a padded tail flush is terminal on the sharded path
    s.flush()
    with pytest.raises(RuntimeError, match="finalized"):
        s.feed(r[:8])
    with pytest.raises(ValueError, match="ragged"):
        stream([q[0], q[1, :4]], impl="sharded")
    with pytest.raises(ValueError, match="scalar excl_zone"):
        stream(q, impl="sharded", top_k=2, excl_zone=np.array([1, 2, 3]))
    with pytest.raises(ValueError, match="prune"):
        stream(q, impl="sharded", top_k=2, prune=True)


def test_stream_argument_validation(rng):
    q = rng.integers(-5, 5, (2, 6)).astype(np.int32)
    with pytest.raises(ValueError, match="prune=True"):
        stream(q, prune=True)
    with pytest.raises(ValueError, match="alerts"):
        stream(q, top_k=2, prune=True, alert_threshold=1)
    # top_k/alerts/prune ride the kernel's last-row capture now; only
    # per-query exclusion zones still force the rowscan tile loop.
    with pytest.raises(ValueError, match="exclusion"):
        stream(q, impl="pallas", excl_lo=1, excl_hi=3)
    with pytest.raises(ValueError, match="excl_mode"):
        stream(q, excl_mode="span")
    with pytest.raises(ValueError, match="together"):
        stream(q, excl_lo=3)
    with pytest.raises(ValueError, match="chunk"):
        stream(q, chunk=0)
    s = stream(q, chunk=8)
    with pytest.raises(ValueError, match="1-D"):
        s.feed(np.zeros((2, 3), np.int32))
    s.feed(np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="dtype"):
        s.feed(np.zeros(4, np.float32))
    # pruned flush is terminal
    s2 = stream(q, chunk=8, top_k=1, prune=True)
    s2.feed(rng.integers(-5, 5, 20).astype(np.int32)).flush()
    with pytest.raises(RuntimeError, match="finalized"):
        s2.feed(np.zeros(8, np.int32))


def test_stream_hypothesis_partition_invariance(rng):
    """Hypothesis property: for random references, random partitions,
    ragged query batches, prune on/off, and a random snapshot/restore
    point, the streamed answer is invariant — exact sessions equal the
    offline engine; pruned sessions equal the same pruned session fed in
    one shot (and their top-1 equals the exact answer)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    M, CHUNK = 40, 8

    @settings(max_examples=20, deadline=None)
    @given(
        ref=st.lists(st.integers(-12, 12), min_size=M, max_size=M),
        cuts=st.lists(st.integers(1, M - 1), max_size=6, unique=True),
        qlens=st.lists(st.integers(1, 8), min_size=1, max_size=3),
        qvals=st.integers(0, 2 ** 31 - 1),
        snap_at=st.integers(0, 6),
        prune=st.booleans(),
    )
    def prop(ref, cuts, qlens, qvals, snap_at, prune):
        r = np.asarray(ref, np.int32)
        qs = [np.random.default_rng(qvals + i).integers(-12, 12, L)
              .astype(np.int32) for i, L in enumerate(qlens)]
        bounds = sorted(set(cuts)) + [M]
        parts = [b - a for a, b in zip([0] + bounds, bounds) if b > a]
        kw = dict(chunk=CHUNK, top_k=2, return_spans=True, prune=prune)
        s = stream(qs, **kw)
        seen = 0
        for i, p in enumerate(parts):
            if i == min(snap_at, len(parts) - 1) and i > 0:
                s = StreamSession.restore(s.snapshot())
            s.feed(r[seen:seen + p])
            seen += p
        res = s.results()
        if prune:
            # deterministic partition invariance + exact top-1
            whole = stream(qs, **kw).feed(r).results()
            np.testing.assert_array_equal(np.asarray(res.distances),
                                          np.asarray(whole.distances))
            np.testing.assert_array_equal(np.asarray(res.positions),
                                          np.asarray(whole.positions))
            exact = sdtw(qs, jnp.asarray(r), top_k=2, return_spans=True)
            np.testing.assert_array_equal(
                np.asarray(res.distances)[:, 0],
                np.asarray(exact[0])[:, 0])
        else:
            want = sdtw(qs, jnp.asarray(r), chunk=CHUNK, top_k=2,
                        return_spans=True)
            np.testing.assert_array_equal(np.asarray(res.distances),
                                          np.asarray(want[0]))
            np.testing.assert_array_equal(np.asarray(res.starts),
                                          np.asarray(want[1]))
            np.testing.assert_array_equal(np.asarray(res.positions),
                                          np.asarray(want[2]))

    prop()


def test_golden_stream_bitwise():
    """Recompute the committed streaming fixture and compare bitwise —
    numeric drift on the streaming path fails loudly. Regenerate (and
    justify) via ``python tests/golden/make_golden.py``."""
    assert GOLDEN.exists(), "golden fixture missing — run " \
        "tests/golden/make_golden.py"
    from golden.make_golden import compute_stream  # noqa: E402
    want = compute_stream()
    with np.load(GOLDEN) as got:
        assert set(got.files) == set(want)
        for key in sorted(want):
            np.testing.assert_array_equal(
                got[key], want[key],
                err_msg=f"golden drift in {key!r} — if intentional, "
                        "regenerate via tests/golden/make_golden.py")


# ---------------------------------------------------------------------------
# Mid-stream flush on k>1 sessions: the boundary-shift caveat
# ---------------------------------------------------------------------------

# A pinned divergence witness: after a mid-stream flush at sample 2 every
# later merge boundary shifts by 2, and the third heap entry lands on a
# different (equal-distance) end than the aligned-boundary offline run —
# while top-1 (and here top-2) stay exact. Found by searching random
# int32 draws with a fold simulation over oracle last rows, then
# verified on the real engine.
FLUSH_SHIFT_Q = np.array([0, 4, 2, 2, 3, 1], np.int32)
FLUSH_SHIFT_R = np.array(
    [4, 0, 1, 1, 2, 2, 0, 0, 0, 0, 0, 4, 0, 3, 3, 1, 1, 2, 1, 4, 0, 4,
     3, 4, 0, 1, 3, 2, 3, 3, 3, 0, 4, 2, 4, 1, 1, 4, 0, 0, 1, 3, 0, 4,
     1, 1, 2, 4, 4, 4, 1, 0, 3, 3, 3, 0, 0, 2, 1, 2, 4, 1, 2, 1, 1],
    np.int32)
FLUSH_SHIFT_CUT = 2


def _flushed_session(k):
    s = stream(FLUSH_SHIFT_Q[None, :], chunk=16, top_k=k)
    s.feed(FLUSH_SHIFT_R[:FLUSH_SHIFT_CUT])
    s.flush()                               # partial tile: boundaries shift
    return s


def test_stream_midflush_k3_warns_and_diverges_beyond_top1():
    """Feeding after a mid-stream flush on a k>1 session warns loudly,
    top-1 stays bitwise-exact, and the pinned witness demonstrates the
    caveat is real: an entry beyond top-1 differs from the offline run."""
    offline = sdtw(jnp.asarray(FLUSH_SHIFT_Q[None, :]),
                   jnp.asarray(FLUSH_SHIFT_R), impl="chunked", chunk=16,
                   top_k=3)
    off_d = np.asarray(offline[0])[0]
    off_p = np.asarray(offline[1])[0]

    s = _flushed_session(k=3)
    with pytest.warns(RuntimeWarning, match="mid-stream flush"):
        s.feed(FLUSH_SHIFT_R[FLUSH_SHIFT_CUT:])
    res = s.results()
    got_d = np.asarray(res.distances)[0]
    got_p = np.asarray(res.positions)[0]

    assert got_d[0] == off_d[0] and got_p[0] == off_p[0]   # top-1 exact
    np.testing.assert_array_equal(got_d, off_d)  # distances agree here
    assert not np.array_equal(got_p, off_p), \
        "witness regressed: boundary shift no longer diverges — find a " \
        "new pinned case before weakening the warning"


def test_stream_midflush_warns_once_then_stays_quiet():
    import warnings as _w
    s = _flushed_session(k=2)
    with pytest.warns(RuntimeWarning, match="mid-stream flush"):
        s.feed(FLUSH_SHIFT_R[FLUSH_SHIFT_CUT:30])
    with _w.catch_warnings():
        _w.simplefilter("error")            # a second warning would raise
        s.feed(FLUSH_SHIFT_R[30:])
        s.results()


def test_stream_midflush_k1_silent():
    """k=1 (and aligned flushes) are exact under any partition — no
    warning may fire."""
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        s = _flushed_session(k=1)
        s.feed(FLUSH_SHIFT_R[FLUSH_SHIFT_CUT:])
        s.results()
        # aligned flush (buffer empty → no partial tile): still silent
        s2 = stream(FLUSH_SHIFT_Q[None, :], chunk=16, top_k=2)
        s2.feed(FLUSH_SHIFT_R[:32])
        s2.flush()
        s2.feed(FLUSH_SHIFT_R[32:])


def test_stream_midflush_pending_survives_snapshot():
    s = _flushed_session(k=2)
    s2 = StreamSession.restore(s.snapshot())
    with pytest.warns(RuntimeWarning, match="mid-stream flush"):
        s2.feed(FLUSH_SHIFT_R[FLUSH_SHIFT_CUT:])
