"""The one test oracle: ground truth for sDTW distances, spans, top-K
selections, and alignment paths.

Every implementation test imports from here — never from a production
module's own reference code — so there is exactly one definition of
"correct" for:

  * distances:  the naive numpy DP of ``repro.core.sdtw_ref`` (Algorithm 1
    plus the standard free-start row), re-exported unchanged;
  * spans:      ``sdtw_span_matrix`` adds the start-pointer lane with the
    shared lexicographic rule — a cell's start is the *smallest* row-0
    column among its minimum-cost paths (value ties break toward the
    smaller start);
  * end picks:  leftmost ``argmin`` of the last row;
  * paths:      ``sdtw_path`` re-runs the DP pinned to the reported start
    and traces predecessors diagonal-first, then left, then up — the same
    deterministic convention ``repro.core.traceback`` implements with
    bounded memory;
  * top-K:      ``greedy_topk`` / ``greedy_topk_spans`` — best-first
    select-then-suppress on the full last row, by end distance or by span
    overlap.

(The former second oracle, the pure-jnp scan of ``repro.kernels.sdtw.ref``,
is gone: its only non-test use was as a benchmark baseline, which now
lives inline in ``benchmarks/sdtw_kernel_bench.py``.)

Everything here is float64 numpy with explicit loops: slow, unambiguous,
and exact for the integer-valued inputs the bitwise tests feed it.
"""
from __future__ import annotations

import numpy as np

from repro.core.distances import INT_BIG
from repro.core.sdtw_ref import dtw_ref, sdtw_matrix, sdtw_ref  # noqa: F401

__all__ = [
    "sdtw_ref", "sdtw_matrix", "dtw_ref",
    "sdtw_span_matrix", "sdtw_span", "sdtw_end",
    "sdtw_path", "greedy_topk", "greedy_topk_spans",
]


def _dist(q, r, metric: str):
    d = np.asarray(q, np.float64) - np.asarray(r, np.float64)
    if metric == "abs_diff":
        return np.abs(d)
    if metric == "square_diff":
        return d * d
    raise ValueError(f"unknown metric {metric!r}")


def sdtw_span_matrix(query, reference, metric: str = "abs_diff",
                     excl_lo=None, excl_hi=None):
    """Full (values, starts) DP: S is the float64 scoring matrix, T[i, j]
    the smallest row-0 column among the minimum-cost paths into (i, j).

    ``excl_lo``/``excl_hi`` ban the half-open reference column range
    ``[excl_lo, excl_hi)`` — banned columns cost inf, exactly the
    engine's per-query exclusion mask (which puts BIG in the distance
    row); a last-row value ≥ INT_BIG / inf therefore means "no
    admissible alignment ends here" on both sides of a differential."""
    q = np.asarray(query, np.float64)
    r = np.asarray(reference, np.float64)
    n, m = len(q), len(r)
    S = np.zeros((n, m))
    T = np.zeros((n, m), np.int64)
    S[0] = _dist(q[0], r, metric)
    T[0] = np.arange(m)
    if excl_lo is not None or excl_hi is not None:
        lo = 0 if excl_lo is None else int(excl_lo)
        hi = 0 if excl_hi is None else int(excl_hi)
        banned = np.zeros((m,), bool)
        banned[max(0, lo):max(0, min(hi, m))] = True
        return _span_matrix_banned(q, r, metric, banned)
    for i in range(1, n):
        di = _dist(q[i], r, metric)
        S[i, 0] = S[i - 1, 0] + di[0]
        T[i, 0] = T[i - 1, 0]
        for j in range(1, m):
            preds = ((S[i - 1, j - 1], T[i - 1, j - 1]),
                     (S[i, j - 1], T[i, j - 1]),
                     (S[i - 1, j], T[i - 1, j]))
            v = min(p[0] for p in preds)
            s = min(p[1] for p in preds if p[0] == v)
            S[i, j] = di[j] + v
            T[i, j] = s
    return S, T


def _span_matrix_banned(q, r, metric, banned):
    """The banned-columns variant of ``sdtw_span_matrix``: a banned
    column's distance row is inf, so no admissible path touches it (inf
    propagates); start pointers follow the same smallest-start rule with
    inf cells keeping a harmless sentinel."""
    n, m = len(q), len(r)
    S = np.zeros((n, m))
    T = np.zeros((n, m), np.int64)
    d0 = _dist(q[0], r, metric)
    d0[banned] = np.inf
    S[0] = d0
    T[0] = np.arange(m)
    for i in range(1, n):
        di = _dist(q[i], r, metric)
        di[banned] = np.inf
        S[i, 0] = S[i - 1, 0] + di[0]
        T[i, 0] = T[i - 1, 0]
        for j in range(1, m):
            preds = ((S[i - 1, j - 1], T[i - 1, j - 1]),
                     (S[i, j - 1], T[i, j - 1]),
                     (S[i - 1, j], T[i - 1, j]))
            v = min(p[0] for p in preds)
            s = min(p[1] for p in preds if p[0] == v)
            S[i, j] = di[j] + v
            T[i, j] = s
    return S, T


def sdtw_span(query, reference, metric: str = "abs_diff"):
    """(distance, start, end): leftmost-argmin end of the last row plus
    that cell's start pointer."""
    S, T = sdtw_span_matrix(query, reference, metric)
    end = int(np.argmin(S[-1]))
    return float(S[-1, end]), int(T[-1, end]), end


def sdtw_end(query, reference, metric: str = "abs_diff") -> int:
    """Leftmost end position attaining the sDTW minimum."""
    return int(np.argmin(sdtw_matrix(query, reference, metric)[-1]))


def sdtw_path(query, reference, start: int, end: int,
              metric: str = "abs_diff") -> np.ndarray:
    """The warping path of span [start, end]: pinned-start window DP (row 0
    finite only at ``start``), traced back diagonal-first, then left, then
    up. Returns (L, 2) (query_row, global_ref_column) pairs, first to
    last."""
    q = np.asarray(query, np.float64)
    w = np.asarray(reference, np.float64)[start:end + 1]
    n, width = len(q), len(w)
    D = _dist(q[:, None], w[None, :], metric)
    S = np.full((n, width), np.inf)
    S[0, 0] = D[0, 0]
    for i in range(1, n):
        S[i, 0] = S[i - 1, 0] + D[i, 0]
        for j in range(1, width):
            S[i, j] = D[i, j] + min(S[i - 1, j - 1], S[i, j - 1],
                                    S[i - 1, j])
    path = []
    i, j = n - 1, width - 1
    while True:
        path.append((i, j))
        if i == 0:
            assert j == 0, "pinned-start path must terminate at column 0"
            break
        here = S[i, j]
        if j > 0 and S[i - 1, j - 1] + D[i, j] == here:
            i, j = i - 1, j - 1
        elif j > 0 and S[i, j - 1] + D[i, j] == here:
            j = j - 1
        else:
            assert S[i - 1, j] + D[i, j] == here
            i = i - 1
    path.reverse()
    out = np.asarray(path, np.int64)
    out[:, 1] += start
    return out


def greedy_topk(last_row, k: int, zone: int):
    """Best-first selection with end-distance suppression on the full DP
    last row (float64) — the semantics ``repro.core.topk`` implements
    streamed. Returns [(distance, end)] with (inf, -1) padding."""
    row = np.asarray(last_row, np.float64).copy()
    out = []
    for _ in range(k):
        j = int(np.argmin(row))
        v = row[j]
        if v >= INT_BIG or not np.isfinite(v):
            out.append((np.inf, -1))
            continue
        out.append((v, j))
        row[np.abs(np.arange(len(row)) - j) <= zone] = np.inf
    return out


def greedy_topk_spans(query, reference, k: int, zone: int,
                      metric: str = "abs_diff", excl_span: bool = False):
    """Span-aware greedy top-K on the full last row: returns
    [(distance, start, end)], suppressing by end distance or (with
    ``excl_span``) by overlap of the zone-widened spans."""
    S, T = sdtw_span_matrix(query, reference, metric)
    row = S[-1].copy()
    starts = T[-1]
    m = len(row)
    out = []
    for _ in range(k):
        j = int(np.argmin(row))
        v = row[j]
        if v >= INT_BIG or not np.isfinite(v):
            out.append((np.inf, -1, -1))
            continue
        s = int(starts[j])
        out.append((v, s, j))
        if excl_span:
            hit = (starts <= j + zone) & (np.arange(m) >= s - zone)
        else:
            hit = np.abs(np.arange(m) - j) <= zone
        row[hit] = np.inf
    return out
