"""The shared systolic pipeline builder, its schedule helper, the (dp, mp)
mesh constructors, and the bounded pipeline cache — everything that runs on
the single local device (multi-device semantics live in
tests/_distributed_check.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.sdtw import sdtw_chunked
import repro.distributed.sdtw_sharded as shmod
from repro.distributed import get_mesh, pipeline_axes
from repro.distributed.sdtw_sharded import (clear_pipeline_cache,
                                            default_mesh, make_schedule,
                                            sdtw_sharded, _cache_size)
from repro.stream import ShardedStreamSession, StreamSession

RNG = np.random.default_rng(7)
QS = jnp.asarray(RNG.integers(-40, 40, (5, 6)).astype(np.int32))
R = jnp.asarray(RNG.integers(-40, 40, (97,)).astype(np.int32))


# ---------------------------------------------------------------------------
# get_mesh / pipeline_axes
# ---------------------------------------------------------------------------

def test_get_mesh_shapes():
    ndev = len(jax.devices())
    m = get_mesh()
    assert m.axis_names == ("mp",) and m.shape["mp"] == ndev
    m = get_mesh((1, -1))
    assert m.axis_names == ("dp", "mp")
    assert m.shape["dp"] == 1 and m.shape["mp"] == ndev
    m = get_mesh(ndev)                       # int → (-1, k), redco-style
    assert m.shape["mp"] == ndev and m.shape["dp"] == 1
    m = get_mesh((-1,), ("ref",))
    assert m.axis_names == ("ref",)


def test_get_mesh_rejects_bad_shapes():
    with pytest.raises(ValueError, match="or .dp, mp."):
        get_mesh((1, 1, 1))
    with pytest.raises(ValueError, match="at most one -1"):
        get_mesh((-1, -1))
    with pytest.raises(ValueError, match="positive or -1"):
        get_mesh((0, 1))
    with pytest.raises(ValueError, match="needs"):
        get_mesh((3, 7))
    with pytest.raises(ValueError, match="not divisible"):
        get_mesh((-1, 3 * len(jax.devices()) + 1))
    with pytest.raises(ValueError, match="axis_names"):
        get_mesh((1, -1), ("only_one",))


def test_pipeline_axes_resolution():
    assert pipeline_axes(default_mesh("ref")) == (None, "ref")
    assert pipeline_axes(get_mesh((1, -1))) == ("dp", "mp")
    assert pipeline_axes(get_mesh()) == (None, "mp")
    # explicit ref_axis wins over the "mp" convention
    m = get_mesh((1, -1), ("rows", "ref"))
    assert pipeline_axes(m, ref_axis="ref") == ("rows", "ref")
    with pytest.raises(ValueError, match="dp_axis"):
        pipeline_axes(get_mesh((1, -1)), dp_axis="nope")
    with pytest.raises(ValueError, match="systolic axis"):
        pipeline_axes(get_mesh((1, -1), ("a", "b")))


# ---------------------------------------------------------------------------
# make_schedule
# ---------------------------------------------------------------------------

def test_make_schedule_defaults_and_packing():
    mesh = get_mesh((1, -1))
    sched = make_schedule(mesh, nq=5)
    assert sched.slots == sched.n_dp * sched.n_micro
    assert sched.slots * sched.mb >= 5
    packed = sched.pack(QS)
    assert packed.shape == (sched.slots, sched.mb, QS.shape[1])
    out = sched.unpack(packed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(QS))


def test_make_schedule_rejects_excess_n_micro():
    mesh = get_mesh()
    with pytest.raises(ValueError, match="exceeds the padded batch"):
        make_schedule(mesh, nq=3, n_micro=4 * len(jax.devices()) + 4)
    with pytest.raises(ValueError, match=">= 1"):
        make_schedule(mesh, nq=3, n_micro=0)
    # default clamps instead of raising
    sched = make_schedule(mesh, nq=1)
    assert sched.n_micro == 1


# ---------------------------------------------------------------------------
# sharded == chunked bitwise on local meshes (incl. a degenerate 2D mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_fn", [
    lambda: default_mesh("ref"), lambda: get_mesh((1, -1))],
    ids=["1d_ref", "2d_dp_mp"])
def test_sharded_matches_chunked_bitwise(mesh_fn):
    mesh = mesh_fn()
    want = np.asarray(sdtw_chunked(QS, R, chunk=8))
    got = np.asarray(sdtw_sharded(QS, R, chunk=8, mesh=mesh))
    np.testing.assert_array_equal(got, want)
    for mode in ("end", "span"):
        tk_c = sdtw_chunked(QS, R, chunk=8, top_k=3, excl_zone=4,
                            excl_mode=mode, return_spans=True)
        tk_s = sdtw_sharded(QS, R, chunk=8, top_k=3, excl_zone=4,
                            excl_mode=mode, return_spans=True, mesh=mesh)
        for a, b in zip(tk_s, tk_c):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sp_c = sdtw_chunked(QS, R, chunk=8, return_spans=True)
    sp_s = sdtw_sharded(QS, R, chunk=8, return_spans=True, mesh=mesh)
    for a, b in zip(sp_s, sp_c):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_n_micro_invariance():
    mesh = default_mesh("ref")
    want = np.asarray(sdtw_sharded(QS, R, chunk=8, mesh=mesh))
    for nm in (1, 2, 5):                     # 5 == nq: ragged tail gone
        got = np.asarray(sdtw_sharded(QS, R, chunk=8, mesh=mesh,
                                      n_micro=nm))
        np.testing.assert_array_equal(got, want, err_msg=f"n_micro={nm}")


# ---------------------------------------------------------------------------
# engine front-door knobs + validation
# ---------------------------------------------------------------------------

def test_engine_mesh_shape_knob():
    want = np.asarray(engine.sdtw(QS, R, chunk=8))
    got = np.asarray(engine.sdtw(QS, R, chunk=8, mesh_shape=(1, -1)))
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="not both"):
        engine.sdtw(QS, R, mesh=get_mesh(), mesh_shape=(1, -1))


def test_engine_sharded_validation():
    with pytest.raises(ValueError, match="n_micro= schedules"):
        engine.sdtw(QS, R, n_micro=2)
    with pytest.raises(ValueError, match="scalar excl_zone"):
        engine.sdtw(QS, R, mesh_shape=(1, -1), top_k=2,
                    excl_zone=np.arange(5))
    with pytest.raises(ValueError, match="already returns"):
        engine.sdtw(QS, R, mesh_shape=(1, -1), top_k=2,
                    return_positions=True)
    with pytest.raises(ValueError, match="exceeds the padded batch"):
        engine.sdtw(QS, R, mesh_shape=(1, -1),
                    n_micro=5 * len(jax.devices()) + 5)
    with pytest.raises(ValueError, match="n_micro= schedules"):
        engine.stream(QS, n_micro=2)


# ---------------------------------------------------------------------------
# bounded pipeline cache
# ---------------------------------------------------------------------------

def test_pipeline_cache_bounded_and_fingerprint_keyed(monkeypatch):
    clear_pipeline_cache()
    assert _cache_size() == 0
    sdtw_sharded(QS, R, chunk=8)
    assert _cache_size() == 1
    sdtw_sharded(QS, R, chunk=8)             # same config: no new entry
    assert _cache_size() == 1
    # distinct Mesh objects over the same devices share one entry
    sdtw_sharded(QS, R, chunk=8, mesh=default_mesh("ref"))
    assert _cache_size() == 1
    sdtw_sharded(QS, R, chunk=8, top_k=2)    # new config: new entry
    assert _cache_size() == 2
    # eviction keeps the cache bounded
    monkeypatch.setattr(shmod, "PIPELINE_CACHE_MAX", 2)
    sdtw_sharded(QS, R, chunk=8, top_k=3)
    assert _cache_size() == 2
    clear_pipeline_cache()
    assert _cache_size() == 0


# ---------------------------------------------------------------------------
# ShardedStreamSession rides the same schedule (degenerate 2D mesh)
# ---------------------------------------------------------------------------

def test_sharded_session_on_2d_mesh_matches_single_process():
    mesh = get_mesh((1, -1))
    sh = ShardedStreamSession(QS, mesh=mesh, chunk=8, top_k=2,
                              return_spans=True)
    sp = StreamSession(QS, chunk=8, top_k=2, return_spans=True)
    r_np = np.asarray(R)
    for off in range(0, r_np.shape[0], 17):
        sh.feed(r_np[off:off + 17])
        sp.feed(r_np[off:off + 17])
    a, b = sh.results(), sp.results()
    for x, y in ((a.distances, b.distances), (a.starts, b.starts),
                 (a.positions, b.positions)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # snapshot → restore keeps the (dp, mp) layout
    sh2 = ShardedStreamSession.restore(sh.snapshot(), mesh=get_mesh((1, -1)))
    np.testing.assert_array_equal(np.asarray(sh2.results().distances),
                                  np.asarray(a.distances))
