"""The unified request surface (repro/core/request.py).

The contract under test: the keyword front doors (``engine.sdtw``,
``engine.stream``, ``search_topk``) are thin shims over
``SdtwRequest``/``StreamRequest``, so the kwargs path and the request
path must produce **bitwise-identical results and byte-identical error
messages** — for every shape class the existing test matrices exercise.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import sdtw, stream
from repro.core.request import SdtwRequest, StreamRequest
from repro.search import search_topk


def _as_np(res):
    if isinstance(res, tuple):
        return tuple(np.asarray(x) for x in res)
    return np.asarray(res)


def _assert_same(a, b):
    if isinstance(a, tuple):
        assert isinstance(b, tuple) and len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# kwargs path == request path, bitwise
# ---------------------------------------------------------------------------

def test_sdtw_request_equals_kwargs_every_shape_class(rng):
    """Dense / single 1-D / padded+qlens / ragged / top-K / spans — the
    request object reproduces the front door bitwise."""
    r = rng.integers(-40, 40, 300).astype(np.int32)
    dense = rng.integers(-40, 40, (3, 12)).astype(np.int32)
    one = rng.integers(-40, 40, 9).astype(np.int32)
    ragged = [rng.integers(-40, 40, n).astype(np.int32)
              for n in (5, 12, 8, 12)]
    qlens = np.array([12, 7, 10], np.int32)
    cases = [
        dict(queries=dense, reference=r),
        dict(queries=one, reference=r),
        dict(queries=dense, reference=r, qlens=qlens),
        dict(queries=ragged, reference=r),
        dict(queries=dense, reference=r, metric="square_diff"),
        dict(queries=dense, reference=r, chunk=32),
        dict(queries=dense, reference=r, top_k=3, excl_zone=4,
             return_spans=True),
        dict(queries=dense, reference=r, top_k=2, excl_mode="span"),
        dict(queries=dense, reference=r, return_positions=True),
        dict(queries=dense, reference=r, impl="wavefront"),
        dict(queries=dense, reference=r, excl_lo=10, excl_hi=40),
    ]
    for kw in cases:
        _assert_same(_as_np(sdtw(**kw)),
                     _as_np(SdtwRequest(**kw).run()))


def test_search_request_equals_kwargs(rng):
    r = rng.integers(-40, 40, 600).astype(np.int32)
    dense = rng.integers(-40, 40, (3, 16)).astype(np.int32)
    ragged = [rng.integers(-40, 40, n).astype(np.int32) for n in (9, 16, 12)]
    for kw in (dict(queries=dense, reference=r, top_k=2),
               dict(queries=ragged, reference=r, top_k=3, excl_zone=5),
               dict(queries=dense, reference=r, top_k=2, prune=False,
                    chunk=64),
               dict(queries=dense, reference=r, top_k=1, normalize=True)):
        want = search_topk(kw["queries"], kw["reference"], kw["top_k"],
                           **{k: v for k, v in kw.items()
                              if k not in ("queries", "reference", "top_k")})
        got = SdtwRequest(op="search_topk", **kw).run()
        np.testing.assert_array_equal(np.asarray(want.distances),
                                      np.asarray(got.distances))
        np.testing.assert_array_equal(np.asarray(want.positions),
                                      np.asarray(got.positions))
        np.testing.assert_array_equal(np.asarray(want.starts),
                                      np.asarray(got.starts))


def test_stream_request_opens_equivalent_session(rng):
    q = rng.integers(-40, 40, (3, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 200).astype(np.int32)
    a = stream(q, chunk=32, top_k=2, excl_zone=4, return_spans=True)
    b = StreamRequest(queries=q, chunk=32, top_k=2, excl_zone=4,
                      return_spans=True).open()
    for lo, hi in ((0, 90), (90, 137), (137, 200)):
        a.feed(r[lo:hi])
        b.feed(r[lo:hi])
    ra, rb = a.results(), b.results()
    np.testing.assert_array_equal(np.asarray(ra.distances),
                                  np.asarray(rb.distances))
    np.testing.assert_array_equal(np.asarray(ra.positions),
                                  np.asarray(rb.positions))


# ---------------------------------------------------------------------------
# identical error messages (the api_redesign no-drift gate)
# ---------------------------------------------------------------------------

def _message(fn, *args, **kw):
    with pytest.raises(ValueError) as ei:
        fn(*args, **kw)
    return str(ei.value)


def test_error_messages_identical_kwargs_vs_request():
    """Every rejection in the existing validation matrix lands the SAME
    message whether raised through the kwargs front door or the request
    object."""
    q = jnp.zeros((2, 4), jnp.int32)
    r = jnp.zeros(16, jnp.int32)
    mesh = object()
    cases = [
        dict(excl_lo=5),
        dict(impl="vibes"),
        dict(impl="rowscan", chunk=8),
        dict(impl="wavefront", mesh=mesh),
        dict(impl="pallas", mesh=mesh),
        dict(impl="chunked", mesh=mesh),
        dict(impl="rowscan", top_k=2),
        dict(impl="pallas", top_k=2),
        dict(top_k=0),
        dict(excl_mode="sideways"),
        dict(excl_mode="span"),
        dict(n_micro=2),
        dict(mesh=mesh, mesh_shape=(1, 1)),
    ]
    for kw in cases:
        got_kwargs = _message(sdtw, q, r, **kw)
        got_request = _message(
            SdtwRequest(queries=q, reference=r, **kw).run)
        assert got_kwargs == got_request, kw

    search_cases = [
        dict(k=0),
        dict(excl_mode="sideways"),
        dict(excl_lo=3),
        dict(engine_impl="vibes"),
        dict(engine_impl="pallas", excl_lo=1, excl_hi=3),
        dict(mesh=mesh),
    ]
    for kw in search_cases:
        k = kw.pop("k", 1)
        got_kwargs = _message(search_topk, q, r, k, **kw)
        got_request = _message(
            SdtwRequest(op="search_topk", queries=q, reference=r,
                        top_k=k, **kw).run)
        assert got_kwargs == got_request, kw

    stream_cases = [
        dict(impl="chunked"),
        dict(excl_mode="sideways"),
        dict(top_k=0),
        dict(excl_lo=2),
        dict(prune=True),
        dict(prune=True, top_k=2, alert_threshold=1.0),
        dict(impl="pallas", excl_lo=1, excl_hi=2),
        dict(chunk=0),
        dict(n_micro=2),
    ]
    for kw in stream_cases:
        got_kwargs = _message(stream, q, **kw)
        got_request = _message(StreamRequest(queries=q, **kw).open)
        assert got_kwargs == got_request, kw


# ---------------------------------------------------------------------------
# request-object mechanics
# ---------------------------------------------------------------------------

def test_requests_are_frozen():
    req = SdtwRequest(queries=np.zeros((1, 4)), reference=np.zeros(8))
    with pytest.raises(dataclasses.FrozenInstanceError):
        req.metric = "square_diff"
    sreq = StreamRequest(queries=np.zeros((1, 4)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        sreq.chunk = 3


def test_from_kwargs_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown SdtwRequest argument"):
        SdtwRequest.from_kwargs(queries=np.zeros((1, 4)),
                                reference=np.zeros(8), exclzone=3)
    with pytest.raises(ValueError, match="unknown StreamRequest argument"):
        StreamRequest.from_kwargs(queries=np.zeros((1, 4)), chnk=16)


def test_search_rejects_array_excl_zone_loudly():
    """Historically ``int()`` silently truncated an array excl_zone; the
    shared validator rejects it with a pointer to the path that honours
    arrays."""
    with pytest.raises(ValueError, match="scalar excl_zone"):
        search_topk(np.zeros((2, 4), np.int32), np.zeros(16, np.int32),
                    1, excl_zone=np.array([1, 2]))


def test_coalesce_key_scalar_vs_array_semantics():
    q = np.zeros((2, 4), np.int32)
    r = np.zeros(16, np.int32)
    a = SdtwRequest(queries=q, reference=r, top_k=2, excl_zone=3)
    b = SdtwRequest(queries=q + 1, reference=r, top_k=2, excl_zone=3.0)
    assert a.coalesce_key("ref") == b.coalesce_key("ref")
    zone = np.array([1, 2])
    c = SdtwRequest(queries=q, reference=r, top_k=2, excl_zone=zone)
    d = SdtwRequest(queries=q, reference=r, top_k=2,
                    excl_zone=zone.copy())
    assert c.coalesce_key("ref") != d.coalesce_key("ref")
    assert a.coalesce_key("ref") != a.coalesce_key("other-ref")


def test_normalized_resolves_mesh_shape():
    req = SdtwRequest(queries=np.zeros((1, 4), np.int32),
                      reference=np.zeros(8, np.int32))
    assert req.normalized() is req
    shaped = dataclasses.replace(req, mesh_shape=1, impl="sharded")
    norm = shaped.normalized()
    assert norm.mesh_shape is None and norm.mesh is not None
