"""The search subsystem: top-K heaps, the LB_Kim/LB_Keogh cascade
(admissibility against a brute-force span-capped oracle), the envelope
cache, and the `search_topk` front door (oracle equivalence with the
engine, pruning exactness, exclusion-zone distinctness, normalization)."""
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import dtw_ref, greedy_topk, sdtw_matrix, sdtw_ref

from repro.core import sdtw
from repro.core.topk import topk_init, topk_merge, topk_select
from repro.search import (EnvelopeCache, chunk_envelope, lb_cascade,
                          search_topk, windowed_envelope, znorm_padded)
from repro.search.search import DEFAULT_SPAN_FACTOR


def heterogeneous_reference(rng, m, seg):
    """Piecewise level-shifted noise — the regime envelope pruning targets."""
    levels = rng.integers(-1500, 1500, -(-m // seg))
    return np.concatenate([
        lvl + rng.normal(0, 40, seg) for lvl in levels])[:m].astype(np.int32)


# ---------------------------------------------------------------------------
# Oracle: search_topk == engine.sdtw (the acceptance sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("chunk", [32, 64, 512])
def test_search_top1_no_prune_bitwise_vs_engine(metric, chunk, rng):
    """k=1, no pruning: distance bitwise-equal to engine.sdtw(), position
    equal to the leftmost argmin of the oracle matrix's last row."""
    q = rng.integers(-40, 40, (4, 12)).astype(np.int32)
    r = rng.integers(-40, 40, 333).astype(np.int32)
    res = search_topk(jnp.asarray(q), jnp.asarray(r), k=1, prune=False,
                      chunk=chunk, metric=metric)
    want = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric))
    np.testing.assert_array_equal(np.asarray(res.distances)[:, 0], want)
    pos_want = [int(np.argmin(sdtw_matrix(q[i], r, metric)[-1]))
                for i in range(4)]
    np.testing.assert_array_equal(np.asarray(res.positions)[:, 0], pos_want)
    assert res.chunks_pruned == 0


def test_search_top1_no_prune_float32(rng):
    """float32: bitwise against the engine's own chunked path (identical
    computation), allclose against the float64 oracle."""
    q = (rng.integers(-40, 40, (3, 9)) + 0.25).astype(np.float32)
    r = (rng.integers(-40, 40, 200) + 0.5).astype(np.float32)
    res = search_topk(jnp.asarray(q), jnp.asarray(r), k=1, prune=False,
                      chunk=32)
    want_d, want_p = sdtw(jnp.asarray(q), jnp.asarray(r), impl="chunked",
                          chunk=32, return_positions=True)
    np.testing.assert_array_equal(np.asarray(res.distances)[:, 0],
                                  np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(res.positions)[:, 0],
                                  np.asarray(want_p))
    oracle = [sdtw_ref(q[i], r) for i in range(3)]
    np.testing.assert_allclose(np.asarray(res.distances)[:, 0], oracle,
                               rtol=1e-5)


def test_search_pruned_top1_exact_and_prunes(rng):
    """Pruning enabled on heterogeneous data: ≥1 chunk pruned, top-1
    distance still bitwise-equal to the engine."""
    ref = heterogeneous_reference(rng, 4096, 512)
    n = 48
    q = np.stack([ref[1000:1000 + n],
                  ref[3000:3000 + n] + rng.integers(-2, 3, n)]).astype(
                      np.int32)
    res = search_topk(jnp.asarray(q), jnp.asarray(ref), k=3, chunk=256)
    want = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(ref)))
    np.testing.assert_array_equal(np.asarray(res.distances)[:, 0], want)
    assert res.chunks_pruned > 0
    assert res.chunks_pruned + res.chunks_processed == res.chunks_total


def test_search_topk_matches_greedy_oracle_no_prune(rng):
    """Full-k streamed heap == greedy suppression on the oracle last row."""
    q = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 150).astype(np.int32)
    k, zone = 4, 6
    res = search_topk(jnp.asarray(q), jnp.asarray(r), k=k, prune=False,
                      chunk=16, excl_zone=zone)
    d = np.asarray(res.distances)
    p = np.asarray(res.positions)
    for i in range(2):
        want = greedy_topk(sdtw_matrix(q[i], r)[-1], k, zone)
        for kk, (wd, wp) in enumerate(want):
            assert p[i, kk] == wp
            if wp >= 0:
                assert d[i, kk] == wd


def test_search_excl_zone_distinct_motifs(rng):
    """Two planted motifs must both surface, positions > excl_zone apart."""
    ref = heterogeneous_reference(rng, 2048, 256)
    n = 32
    motif = rng.integers(-3000, -2500, n).astype(np.int32)  # out-of-range
    ref[400:400 + n] = motif
    ref[1500:1500 + n] = motif + 1
    res = search_topk(jnp.asarray(motif), jnp.asarray(ref), k=2, chunk=128)
    pos = sorted(int(x) for x in np.asarray(res.positions))
    assert pos == [400 + n - 1, 1500 + n - 1]
    for a in np.asarray(res.positions):
        for b in np.asarray(res.positions):
            assert a == b or abs(int(a) - int(b)) > n // 2


# ---------------------------------------------------------------------------
# Lower-bound admissibility
# ---------------------------------------------------------------------------

def span_capped_best(q, r, j_range, cap, metric):
    """Brute force: cheapest alignment of the whole query ending at any
    j in j_range with warping span <= cap columns (pinned-ends DTW over
    every allowed window)."""
    best = np.inf
    for j in j_range:
        for a in range(max(0, j - cap + 1), j + 1):
            best = min(best, dtw_ref(q, r[a:j + 1], metric))
    return best


@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
def test_lb_cascade_admissible_vs_bruteforce(metric, rng):
    """Neither bound may exceed the true cost of the best span-capped match
    ending in its chunk, and LB_Keogh dominates LB_Kim."""
    nq, n, m, chunk = 2, 5, 40, 8
    cap = DEFAULT_SPAN_FACTOR * n
    halo = -(-cap // chunk)
    for trial in range(5):
        q = rng.integers(-30, 30, (nq, n)).astype(np.int32)
        r = rng.integers(-30, 30, m).astype(np.int32)
        mins, maxs = chunk_envelope(jnp.asarray(r), chunk)
        qlens = jnp.full((nq,), n, jnp.int32)
        kim, keogh = lb_cascade(jnp.asarray(q), qlens, mins, maxs, halo,
                                metric)
        kim, keogh = np.asarray(kim), np.asarray(keogh)
        assert np.all(kim <= keogh + 1e-4)
        for c in range(-(-m // chunk)):
            js = range(c * chunk, min(m, (c + 1) * chunk))
            for i in range(nq):
                true = span_capped_best(q[i], r, js, cap, metric)
                assert kim[i, c] <= true + 1e-6, (trial, i, c)
                assert keogh[i, c] <= true + 1e-6, (trial, i, c)


def test_lb_never_prunes_best_chunk(rng):
    """With span_cap covering the whole reference (unconditional bounds),
    the chunk holding the true best match always bounds at or below the
    true best distance — pruning can never drop it."""
    n, m, chunk = 6, 96, 16
    halo = -(-m // chunk)                      # window = everything left
    for trial in range(20):
        q = rng.integers(-50, 50, n).astype(np.int32)
        r = rng.integers(-50, 50, m).astype(np.int32)
        if trial % 3 == 0:
            s = int(rng.integers(0, m - n))
            r[s:s + n] = q                     # planted exact match
        d, p = sdtw(jnp.asarray(q), jnp.asarray(r), return_positions=True)
        mins, maxs = chunk_envelope(jnp.asarray(r), chunk)
        kim, keogh = lb_cascade(q[None, :].astype(np.int32),
                                jnp.asarray([n], jnp.int32), mins, maxs,
                                halo)
        c_best = int(p) // chunk
        assert float(np.asarray(kim)[0, c_best]) <= float(d) + 1e-6
        assert float(np.asarray(keogh)[0, c_best]) <= float(d) + 1e-6


def test_lb_admissibility_hypothesis(rng):
    """Property-based version of the brute-force admissibility check."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    n, m, chunk = 4, 24, 8
    cap = DEFAULT_SPAN_FACTOR * n
    halo = -(-cap // chunk)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(-20, 20), min_size=n + m, max_size=n + m))
    def prop(vals):
        q = np.asarray(vals[:n], np.int32)
        r = np.asarray(vals[n:], np.int32)
        mins, maxs = chunk_envelope(jnp.asarray(r), chunk)
        _, keogh = lb_cascade(q[None, :], jnp.asarray([n], jnp.int32),
                              mins, maxs, halo)
        keogh = np.asarray(keogh)[0]
        for c in range(-(-m // chunk)):
            js = range(c * chunk, min(m, (c + 1) * chunk))
            true = span_capped_best(q, r, js, cap, "abs_diff")
            assert keogh[c] <= true + 1e-6

    prop()


def test_windowed_envelope_widens_left():
    mins = jnp.asarray([0., 10., -5., 3.])
    maxs = jnp.asarray([1., 12., -2., 4.])
    wmin, wmax = windowed_envelope(mins, maxs, 1)
    np.testing.assert_allclose(np.asarray(wmin), [0., 0., -5., -5.])
    np.testing.assert_allclose(np.asarray(wmax), [1., 12., 12., 4.])


# ---------------------------------------------------------------------------
# Top-K heap primitives
# ---------------------------------------------------------------------------

def test_topk_select_suppression_and_padding():
    scores = jnp.asarray([5., 3., 4., 9., 1.], jnp.float32)
    pos = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
    starts = pos - 1
    d, p, s = topk_select(scores, pos, starts, 3, 1)
    # 1@4 suppresses 9@3; 3@1 suppresses 5@0 and 4@2 → only 2 matches.
    np.testing.assert_array_equal(np.asarray(p), [4, 1, -1])
    np.testing.assert_array_equal(np.asarray(s), [3, 0, -1])
    assert np.asarray(d)[2] == np.inf


def test_topk_select_span_overlap_mode():
    """excl_span suppresses on interval intersection, not end distance:
    a far-ended candidate whose span reaches back over the pick dies; a
    close-ended but disjoint one survives."""
    scores = jnp.asarray([1., 2., 3.], jnp.float32)
    ends = jnp.asarray([10, 40, 13], jnp.int32)
    starts = jnp.asarray([5, 8, 12], jnp.int32)    # [5,10], [8,40], [12,13]
    d, p, s = topk_select(scores, ends, starts, 3, 0, excl_span=True)
    # pick [5,10] → kills [8,40] (overlap) but keeps disjoint [12,13],
    # even though end 13 is nearer than end 40.
    np.testing.assert_array_equal(np.asarray(p), [10, 13, -1])
    np.testing.assert_array_equal(np.asarray(s), [5, 12, -1])


def test_topk_merge_tie_prefers_heap():
    """Exact ties keep the earlier (heap/earlier-chunk) position."""
    hd, hp, hs = topk_init(1, 1, jnp.float32)
    d1, p1, s1 = topk_merge(hd[0], hp[0], hs[0],
                            jnp.asarray([7.], jnp.float32),
                            jnp.asarray([10], jnp.int32),
                            jnp.asarray([8], jnp.int32), 1, 2)
    d2, p2, s2 = topk_merge(d1, p1, s1, jnp.asarray([7.], jnp.float32),
                            jnp.asarray([50], jnp.int32),
                            jnp.asarray([48], jnp.int32), 1, 2)
    assert int(p2[0]) == 10 and float(d2[0]) == 7.0 and int(s2[0]) == 8


# ---------------------------------------------------------------------------
# Front-door plumbing
# ---------------------------------------------------------------------------

def test_envelope_cache_hits(rng):
    r = jnp.asarray(rng.integers(-40, 40, 128).astype(np.int32))
    cache = EnvelopeCache()
    e1 = cache.envelope(r, 32, key="k")
    e2 = cache.envelope(r, 32, key="k")
    assert cache.hits == 1 and cache.misses == 1 and len(cache) == 1
    np.testing.assert_array_equal(np.asarray(e1[0]), np.asarray(e2[0]))
    cache.envelope(r, 16, key="k")             # different chunk → new entry
    assert cache.misses == 2
    # Fingerprint path (no key) is deterministic.
    cache.envelope(r, 32)
    cache.envelope(r, 32)
    assert cache.hits == 2 and cache.misses == 3


def test_cache_key_isolates_normalized_searches(rng):
    """A normalized and a raw search sharing ref_key must not share
    envelope entries — a stale raw envelope would mis-prune the
    normalized search (and vice versa)."""
    ref = heterogeneous_reference(rng, 2048, 256)
    n = 32
    q = ref[900:900 + n].astype(np.int32)
    cache = EnvelopeCache()
    res_n = search_topk(jnp.asarray(q), jnp.asarray(ref), k=1, chunk=128,
                        normalize=True, cache=cache, ref_key="shared")
    res_r = search_topk(jnp.asarray(q), jnp.asarray(ref), k=1, chunk=128,
                        cache=cache, ref_key="shared")
    assert cache.misses == 2 and len(cache) == 2   # no cross-contamination
    want = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(ref)))
    assert np.asarray(res_r.distances)[0] == want  # raw prune still exact
    assert np.isfinite(float(res_n.distances[0]))


def test_ragged_search_matches_per_query(rng):
    r = rng.integers(-50, 50, 200).astype(np.int32)
    ragged = [rng.integers(-50, 50, L).astype(np.int32) for L in (5, 17, 9)]
    res = search_topk([jnp.asarray(x) for x in ragged], jnp.asarray(r),
                      k=2, prune=False, chunk=32, excl_zone=3)
    for i, q in enumerate(ragged):
        one = search_topk(jnp.asarray(q), jnp.asarray(r), k=2, prune=False,
                          chunk=32, excl_zone=3)
        np.testing.assert_array_equal(np.asarray(res.distances)[i],
                                      np.asarray(one.distances))
        np.testing.assert_array_equal(np.asarray(res.positions)[i],
                                      np.asarray(one.positions))


def test_normalize_finds_scaled_motif(rng):
    """A gain/offset-shifted copy of a reference window (different sensor
    calibration) is found only after z-normalization. The reference is a
    fast quasi-random oscillation so every window shares the global
    moments — the regime global z-norm is exact for."""
    ref = (100 * np.sin(np.arange(512) * 2.63)
           + rng.normal(0, 2, 512)).astype(np.float32)
    n = 40
    motif = ref[300:300 + n] * 3.0 + 2000.0    # scaled + offset copy
    res = search_topk(jnp.asarray(motif), jnp.asarray(ref), k=1,
                      normalize=True, chunk=64, prune=False)
    assert abs(int(res.positions[0]) - (300 + n - 1)) <= 2
    mask_aware = znorm_padded(jnp.asarray(motif)[None, :],
                              jnp.asarray([n], jnp.int32))
    assert abs(float(jnp.mean(mask_aware))) < 1e-5


def test_search_arg_validation(rng):
    q = jnp.zeros((2, 4), jnp.int32)
    r = jnp.zeros(32, jnp.int32)
    with pytest.raises(ValueError, match="k must be"):
        search_topk(q, r, k=0)
    with pytest.raises(ValueError, match="prune=False"):
        search_topk(q, r, mesh=object())
