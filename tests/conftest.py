import os

# Smoke tests must see the real single CPU device — never the dry-run's 512
# forced host devices (set only inside repro.launch.dryrun / subprocesses).
assert "--xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not run with forced host device count"

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
