"""Optimizer + gradient-compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.collectives import (compress_with_feedback,
                                           dequantize_int8, init_feedback,
                                           quantize_int8)
from repro.optim import (OptConfig, adamw_update, clip_by_global_norm,
                         global_norm, init_opt, schedule)


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = init_opt(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=200, min_lr_frac=1.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}     # d/dw w²
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_weight_decay_shrinks_params():
    params = {"w": jnp.ones(3)}
    opt = init_opt(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.5, warmup_steps=0)
    params2, _, _ = adamw_update(cfg, params, {"w": jnp.zeros(3)}, opt)
    assert float(params2["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 20.0)
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    s = lambda t: float(schedule(cfg, jnp.asarray(t)))
    assert s(0) < s(9) <= 1.0           # warmup rising
    assert abs(s(10) - 1.0) < 0.1       # peak
    assert s(99) < 0.2                  # decayed
    assert s(99) >= 0.1 * 1.0 - 1e-6    # floor


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, 512).astype(np.float32))
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) / 2 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """With a constant gradient, error feedback makes the *sum* of delivered
    gradients converge to the sum of true gradients."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))}
    fb = init_feedback(g)
    delivered = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        deq, fb = compress_with_feedback(g, fb)
        delivered = delivered + deq["w"]
    err = float(jnp.max(jnp.abs(delivered / n - g["w"])))
    assert err < 1e-3
