"""MATSA analytic simulator vs the paper's published claims (Table VI,
Key Observations 3-6, endurance)."""
import statistics

import pytest

from repro.core import (PAPER_TABLE6, PLATFORMS, VERSIONS, MramParams,
                        OpCounts, Workload, endurance_writes_per_cell,
                        load_real_workload_shapes, simulate)


def _ratios(version, platform):
    v, p = VERSIONS[version], PLATFORMS[platform]
    sp, en = [], []
    for s in load_real_workload_shapes().values():
        w = Workload(s["ref_size"], s["query_size"], s["num_queries"])
        r = simulate(w, v.compute_columns)
        sp.append(p.exec_time_s(w) / r.exec_time_s)
        en.append(p.energy_j(w) / r.energy_j)
    return statistics.geometric_mean(sp), statistics.geometric_mean(en)


@pytest.mark.parametrize("pair", sorted(PAPER_TABLE6))
def test_table6_within_tolerance(pair):
    """Speedups within 15%, energy within 5% of the paper's Table VI."""
    sp, en = _ratios(*pair)
    want_sp, want_en = PAPER_TABLE6[pair]
    assert abs(sp / want_sp - 1) < 0.15, (pair, sp, want_sp)
    assert abs(en / want_en - 1) < 0.05, (pair, en, want_en)


def test_key3_write_latency_dominates():
    """Key Obs 3: low write latency is crucial (write share > read share)."""
    w = Workload(131072, 8192, 8192)
    r = simulate(w, 131072)
    assert r.read_time_frac < 0.5


def test_key3_fig9_calibrated_counts():
    """With the Fig.9-calibrated count ratio, 10× latency endpoints land on
    the paper's 4.7× / 6.5× (other latency at the sweep floor)."""
    counts = OpCounts.derive(preset="fig9_calibrated")
    w = Workload(131072, 8192, 8192)
    t = lambda rd, wr: simulate(
        w, 131072, MramParams(read_ns=rd, write_ns=wr), counts).exec_time_s
    assert abs(t(10, 1) / t(1, 1) - 4.7) < 0.3
    assert abs(t(1, 10) / t(1, 1) - 6.5) < 0.4


def test_key4_energy_split():
    """Key Obs 4: read ≈45% / write ≈55% of energy (ours: 42/58)."""
    r = simulate(Workload(131072, 8192, 8192), 131072)
    assert 0.35 < r.read_energy_frac < 0.5


def test_key5_proportionality():
    """Key Obs 5: time & energy proportional to ref_size × query_size."""
    base = simulate(Workload(65536, 4096, 4096), 131072)
    both = simulate(Workload(131072, 8192, 4096), 131072)
    assert abs(both.exec_time_s / base.exec_time_s - 4) < 0.1
    assert abs(both.energy_j / base.energy_j - 4) < 1e-6


def test_key6_near_ideal_scaling():
    """Key Obs 6: doubling columns ≈ halves time, same energy."""
    w = Workload(131072, 8192, 8192)
    t1 = simulate(w, 131072)
    t2 = simulate(w, 262144)
    assert 1.9 < t1.exec_time_s / t2.exec_time_s < 2.05
    assert t1.energy_j == t2.energy_j


def test_endurance_conclusion():
    """SOT-MRAM (1e15 writes) survives a decade of 24/7 use; ReRAM (1e5)
    fails almost immediately — the paper's §IV-B conclusion."""
    writes_10y = endurance_writes_per_cell(years=10)
    assert writes_10y < 1e15          # SOT-MRAM survives
    seconds_to_rerAM_death = 1e5 / (writes_10y / (10 * 365.25 * 24 * 3600))
    assert seconds_to_rerAM_death < 24 * 3600  # ReRAM dies within a day


def test_square_diff_costlier_than_abs():
    a = OpCounts.derive(metric="abs_diff")
    s = OpCounts.derive(metric="square_diff")
    assert s.reads > a.reads and s.writes > a.writes


def test_work_conserving_vs_granular():
    w = Workload(1_800_000, 512, 16384)   # ECG-like: M > columns
    wc = simulate(w, 1_048_576, work_conserving=True)
    gr = simulate(w, 1_048_576, work_conserving=False)
    assert wc.exec_time_s < gr.exec_time_s


# ---------------------------------------------------------------------------
# Hypothesis monotonicity properties — the simulator's partial order.
# ---------------------------------------------------------------------------

def test_cost_monotone_in_workload_dimensions():
    """Growing any of W (operand width), M (ref_size), n_q (num_queries)
    — or the query size — never decreases time or energy."""
    pytest.importorskip("hypothesis")
    import dataclasses

    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(64, 1 << 21), n=st.integers(8, 4096),
           nq=st.integers(1, 1 << 15), scale=st.integers(2, 8),
           cols=st.sampled_from([32768, 131072, 1048576]),
           dim=st.sampled_from(["ref_size", "query_size", "num_queries"]),
           conserving=st.booleans())
    def prop(m, n, nq, scale, cols, dim, conserving):
        w = Workload(m, n, nq)
        base = simulate(w, cols, work_conserving=conserving)
        w2 = dataclasses.replace(w, **{dim: getattr(w, dim) * scale})
        grown = simulate(w2, cols, work_conserving=conserving)
        assert grown.exec_time_s >= base.exec_time_s, (dim, w)
        assert grown.energy_j >= base.energy_j, (dim, w)
        # width monotonicity enters through the per-cell op counts
        wide = simulate(dataclasses.replace(w, width=64), cols)
        narrow = simulate(dataclasses.replace(w, width=16), cols)
        assert wide.exec_time_s >= narrow.exec_time_s

    prop()


def test_replication_never_hurts():
    """Reference replication (§III-D: R = C // M spare-column copies, so
    it exists when the reference fits the columns) never slows a workload
    down, never changes its energy, and the work-conserving repacking
    never loses to the ceil-granular schedule.

    The m <= cols guard is load-bearing: for C < M < 2C doubling the
    columns grows the pipeline-fill term (min(M, C) - 1) by up to C while
    the compute term shrinks by ~cells/2C, so fill-dominated workloads
    can get *slower* — that regime has no replication at all (R = 0), so
    it is outside the claim. The compute steps alone are monotone
    unconditionally, asserted separately."""
    pytest.importorskip("hypothesis")
    import math

    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(64, 1 << 18), n=st.integers(8, 4096),
           nq=st.integers(1, 1 << 15),
           cols=st.sampled_from([262144, 1048576]),
           conserving=st.booleans())
    def prop(m, n, nq, cols, conserving):
        w = Workload(m, n, nq)      # m <= 2^18 <= cols: replication regime
        small = simulate(w, cols, work_conserving=conserving)
        doubled = simulate(w, 2 * cols, work_conserving=conserving)
        assert doubled.exec_time_s <= small.exec_time_s, w
        assert doubled.energy_j == small.energy_j     # same cells
        # Steady-state compute steps are monotone for every shape.
        fill_s = min(m, cols) - 1
        fill_d = min(m, 2 * cols) - 1
        assert (doubled.macro_steps - fill_d
                <= small.macro_steps - fill_s)
        assert doubled.macro_steps - fill_d >= math.ceil(
            w.num_queries * w.query_size * w.ref_size / (2 * cols))
        wc = simulate(w, cols, work_conserving=True)
        gr = simulate(w, cols, work_conserving=False)
        assert wc.exec_time_s <= gr.exec_time_s, w

    prop()
