"""Launch-layer units: input specs, collective parser, roofline math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, all_archs, cells, get_arch
from repro.launch.roofline import collective_bytes, model_flops, roofline
from repro.launch.specs import input_specs, run_config_for
from repro.models import RunConfig


def test_cells_enumeration():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40                    # 10 archs × 4 shapes
    skipped = [c for c in all_cells if c[2]]
    assert len(skipped) == 8                       # long_500k × 8 quadratic
    assert all(s.name == "long_500k" for _, s, sk in skipped if sk)


def test_input_specs_shapes():
    run = RunConfig()
    for name, cfg in all_archs().items():
        for sname, shape in SHAPES.items():
            spec = input_specs(cfg, shape, run)
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch,)
            elif cfg.frontend == "stub":
                assert spec["embeddings"].shape == (
                    shape.global_batch, shape.seq_len, cfg.d_model)
            else:
                assert spec["tokens"].shape == (shape.global_batch,
                                                shape.seq_len)


def test_collective_parser():
    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %y), to_apply=%sum
  %ars = f32[64]{0} all-reduce-start(f32[64]{0} %z)
  %ard = f32[64]{0} all-reduce-done(f32[64]{0} %ars)
  %t = (f32[32]{0}, f32[32]{0}) all-to-all(f32[32]{0} %a, f32[32]{0} %b)
  %cp = u32[2]{0} collective-permute(u32[2]{0} %c)
  %rs = bf16[8,16]{1,0} reduce-scatter(bf16[64,16]{1,0} %d)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 4 * 1024 * 2
    assert out["bytes"]["all-reduce"] == 128 * 4 + 64 * 4  # -done skipped
    assert out["counts"]["all-reduce"] == 2
    assert out["bytes"]["all-to-all"] == 2 * 32 * 4
    assert out["bytes"]["collective-permute"] == 2 * 4
    assert out["bytes"]["reduce-scatter"] == 8 * 16 * 2
    assert out["total_bytes"] == sum(out["bytes"].values())


def test_roofline_terms_math():
    t = roofline(flops_per_chip=197e12, bytes_per_chip=819e9,
                 coll_bytes_per_chip=50e9, model_flops=197e12 * 256,
                 n_chips=256)
    assert np.isclose(t.compute_s, 1.0)
    assert np.isclose(t.memory_s, 1.0)
    assert np.isclose(t.collective_s, 1.0)
    assert np.isclose(t.useful_flops_ratio, 1.0)
    assert t.dominant in ("compute", "memory", "collective")


def test_model_flops_ordering():
    """train > prefill > decode for a given arch; MoE active < total."""
    cfg = get_arch("llama3.2-1b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > 0 and pf > 0 and dec > 0
    assert tr > dec and pf > dec
    moe = get_arch("qwen3-moe-30b-a3b")
    assert moe.active_param_count() < moe.param_count() / 3


def test_run_config_for_shapes():
    assert run_config_for(SHAPES["train_4k"]).remat == "full"
    assert run_config_for(SHAPES["prefill_32k"]).attn_mode == "chunked"
    assert run_config_for(SHAPES["decode_32k"]).remat == "none"
    rc = run_config_for(SHAPES["train_4k"], {"attn_mode": "triangular"})
    assert rc.attn_mode == "triangular"
