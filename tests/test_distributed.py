"""Multi-device semantics (8 fake CPU devices, subprocess so the main test
process keeps its single real device)."""
import os
import subprocess
import sys

import pytest


def _run_check(extra_args=()):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = os.path.join(os.path.dirname(__file__), "_distributed_check.py")
    return subprocess.run([sys.executable, script, *extra_args], env=env,
                          capture_output=True, text=True, timeout=1200)


@pytest.mark.slow
def test_distributed_semantics_subprocess():
    res = _run_check()
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "DISTRIBUTED_ALL_OK" in res.stdout


@pytest.mark.slow
@pytest.mark.parametrize("shape", ["1,8", "2,4", "4,2"])
def test_distributed_sdtw_mesh_shapes(shape):
    """Every (dp, mp) factorization of the 8 devices runs the full sDTW
    check body (batch / top-K both modes / spans / stream, all bitwise
    against the single-device engine)."""
    res = _run_check(["--sdtw-mesh", shape])
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "DISTRIBUTED_SDTW_OK" in res.stdout
