"""Multi-device semantics (8 fake CPU devices, subprocess so the main test
process keeps its single real device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_distributed_semantics_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = os.path.join(os.path.dirname(__file__), "_distributed_check.py")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "DISTRIBUTED_ALL_OK" in res.stdout
